//! Residual (delta) framing equivalence (engine-free): the same
//! miniature `fl::Server` mirror as `integration_async.rs`, but with
//! `fl::DeltaFrameState` threaded through the uplink and downlink
//! accounting exactly as `Server` wires it — reference snapshots from
//! decoded uploads and recent broadcasts, delta frames on the comm
//! ledger, self-contained lengths on the link schedule.
//!
//! Pins the PR's acceptance invariants:
//! * **trajectory invariance** — delta-framed FedAvg and FedLUAR runs
//!   (sync and `async:c=N`) are bit-identical in every model-path and
//!   clock field to their dense-framed twins; only byte columns move;
//! * **strictly fewer bytes** — uplink ledger bytes strictly shrink on
//!   runs of two or more rounds, downlink never grows, and
//!   `delta_bytes_saved` equals the dense-vs-delta ledger gap exactly;
//! * **fallbacks counted** — every first-contact transmission (both
//!   directions) shows up in `delta_fallbacks`.
//!
//! The synthetic client deltas here are cross-round correlated by
//! construction: one base draw per client, per-generation noise XORed
//! into the low 16 mantissa bits. Successive uploads then differ only
//! in bytes the XOR coder stores at 2-per-element, so uplink savings
//! are a deterministic guarantee, not a distributional accident.

use fedluar::comm::CommAccountant;
use fedluar::config::{RecycleMode, SelectionScheme};
use fedluar::fl::{AsyncRuntime, DeltaFrameState, UploadPayload};
use fedluar::luar::LuarState;
use fedluar::metrics::{History, RoundRecord};
use fedluar::model::ModelMeta;
use fedluar::net::{wire, LinkDist, NetCfg, NetSim, RoundMode, Staleness};
use fedluar::rng::Rng;
use fedluar::tensor;
use std::path::PathBuf;

const LAYERS: usize = 6;
const LAYER_SIZE: usize = 512;
const NUM_CLIENTS: usize = 16;
const ACTIVE: usize = 8;

fn synth_meta() -> ModelMeta {
    let mut rows = Vec::new();
    for l in 0..LAYERS {
        let off = l * LAYER_SIZE;
        rows.push(format!(
            r#"{{"name":"l{l}","kind":"dense","offset":{off},"size":{LAYER_SIZE},
               "arrays":[{{"name":"w","shape":[8,64],"offset":{off},"size":{LAYER_SIZE}}}]}}"#
        ));
    }
    let dim = LAYERS * LAYER_SIZE;
    let doc = format!(
        r#"{{"model":"dsim","dim":{dim},"num_classes":10,
            "input_shape":[8],"input_dtype":"f32","tau":5,"batch":16,
            "eval_batch":64,"agg_clients":8,"momentum":0.9,
            "layers":[{}],
            "artifacts":{{"train":"t","eval":"e","agg":"g","init":"i"}},
            "init_sha256":"x"}}"#,
        rows.join(",")
    );
    ModelMeta::from_json(&doc, PathBuf::from("/tmp")).unwrap()
}

/// Cross-round-correlated stand-in for one client's local training:
/// a per-client base vector with per-generation noise confined to the
/// low 16 bits of each f32 — the regime (and bit layout) residual
/// framing exists to exploit.
fn fake_delta(seed: u64, client: usize, gen: u64, dim: usize) -> (Vec<f32>, f32) {
    let mut base = Rng::seed_from_u64(seed ^ (client as u64).wrapping_mul(0x9e37_79b9));
    let mut noise = Rng::seed_from_u64(
        seed ^ (client as u64).wrapping_mul(0x9e37_79b9) ^ gen.wrapping_mul(0x85eb_ca6b),
    );
    let delta: Vec<f32> = (0..dim)
        .map(|_| {
            let b = base.normal_f32(0.0, 0.05);
            f32::from_bits(b.to_bits() ^ (noise.next_u64() as u32 & 0xffff))
        })
        .collect();
    let loss = 1.0 + noise.f32();
    (delta, loss)
}

/// Miniature mirror of `fl::Server` with the residual-framing ledger:
/// dense codec + link schedule unchanged (timing is always against
/// self-contained lengths), `DeltaFrameState` deciding what the comm
/// ledger records, `drain_round` feeding `CommAccountant::record_delta`
/// — the exact dataflow of `Server::client_upload` /
/// `run_sync_round` / `dispatch_next_async` / `finish_aggregation`.
struct DeltaSim {
    meta: ModelMeta,
    seed: u64,
    luar_delta: Option<usize>,
    net: NetSim,
    luar: LuarState,
    params: Vec<f32>,
    comm: CommAccountant,
    history: History,
    rng: Rng,
    round: usize,
    sim_seconds: f64,
    rt: Option<AsyncRuntime>,
    delta: Option<DeltaFrameState>,
}

impl DeltaSim {
    fn new(mode: RoundMode, luar_delta: Option<usize>, seed: u64, delta_frames: bool) -> Self {
        let meta = synth_meta();
        let net = NetSim::new(
            NetCfg {
                link_dist: LinkDist::default(),
                round_mode: mode,
                compute_s: 0.1,
                delta_frames,
            },
            NUM_CLIENTS,
            42,
        );
        let dim = meta.dim;
        let layers = meta.num_layers();
        DeltaSim {
            meta,
            seed,
            luar_delta,
            net,
            luar: LuarState::new(layers, dim),
            params: vec![0.0; dim],
            comm: CommAccountant::new(layers),
            history: History::default(),
            rng: Rng::seed_from_u64(seed ^ 0xc0ffee),
            round: 0,
            sim_seconds: 0.0,
            rt: None,
            delta: delta_frames.then(|| DeltaFrameState::new(NUM_CLIENTS)),
        }
    }

    fn cohort(&self, gen: u64) -> Vec<usize> {
        (0..ACTIVE).map(|i| ((gen as usize) * ACTIVE + i) % NUM_CLIENTS).collect()
    }

    fn upload_layers(&self) -> Vec<usize> {
        if self.luar_delta.is_some() {
            self.luar.upload_set(self.meta.num_layers())
        } else {
            (0..self.meta.num_layers()).collect()
        }
    }

    /// One client's uplink at model `version`: train (fake), zero R_t,
    /// dense encode/decode (self-contained length times the link), then
    /// the residual path decides the ledger length — exactly
    /// `Server::client_upload`. Returns (decoded update, loss,
    /// ledger bytes, self-contained bytes).
    fn upload(
        &mut self,
        client: usize,
        gen: u64,
        version: u64,
        upload_layers: &[usize],
    ) -> (Vec<f32>, f32, u64, u64) {
        let (mut delta_v, loss) = fake_delta(self.seed, client, gen, self.meta.dim);
        for &l in &self.luar.recycle_set {
            let lm = &self.meta.layers[l];
            delta_v[lm.offset..lm.offset + lm.size].iter_mut().for_each(|v| *v = 0.0);
        }
        let frame =
            wire::encode_update(&delta_v, &self.meta, upload_layers, &wire::WireHint::Dense)
                .unwrap();
        let mut decoded = match wire::decode_update(frame.as_bytes(), &self.meta).unwrap() {
            wire::Decoded::Vector(v) => v,
            wire::Decoded::Scalar(_) => unreachable!("dense flavor only"),
        };
        let self_len = frame.len() as u64;
        let mut ledger_len = self_len;
        if let Some(st) = &self.delta {
            if let Some(ref_version) = st.usable_up_ref_version(client, version) {
                let reference = st.up_ref(client).expect("usable ref exists").data.clone();
                let dframe = wire::encode_update_delta(
                    &decoded,
                    &self.meta,
                    upload_layers,
                    &reference,
                    ref_version,
                )
                .unwrap();
                if (dframe.len() as u64) < self_len {
                    let (dd, _) =
                        wire::decode_update_delta(dframe.as_bytes(), &self.meta, &reference)
                            .unwrap();
                    ledger_len = dframe.len() as u64;
                    decoded = dd;
                    let st = self.delta.as_mut().expect("checked above");
                    st.note_uplink(self_len, ledger_len, Some(version - ref_version));
                } else {
                    let st = self.delta.as_mut().expect("checked above");
                    st.note_uplink(self_len, self_len, None);
                }
            } else {
                let st = self.delta.as_mut().expect("checked above");
                st.note_uplink(self_len, self_len, None);
            }
            let st = self.delta.as_mut().expect("checked above");
            st.record_upload(client, version, &decoded, &self.meta);
        }
        (decoded, loss, ledger_len, self_len)
    }

    /// Absorb half: weighted mean, LUAR scores/aging/compose, SGD
    /// apply, ledger (now including the drained residual counters).
    #[allow(clippy::too_many_arguments)]
    fn finish(
        &mut self,
        deltas: &[Vec<f32>],
        included: &[bool],
        weights: &[f32],
        upload_layers: &[usize],
        actives_len: usize,
        loss_sum: f64,
        loss_count: usize,
        up_bytes_total: u64,
        down_total: u64,
        round_secs: f64,
        tail_s: f64,
        arrivals: usize,
        mean_gap: f64,
    ) {
        let mut refs: Vec<&[f32]> = Vec::with_capacity(arrivals);
        let mut agg_weights: Vec<f32> = Vec::with_capacity(arrivals);
        for (slot, d) in deltas.iter().enumerate() {
            if included[slot] {
                refs.push(d.as_slice());
                agg_weights.push(weights[slot]);
            }
        }
        assert!(!refs.is_empty(), "aggregation must never be empty");
        let uniform = agg_weights.iter().all(|&w| w == 1.0);
        let mut mean = vec![0.0f32; self.meta.dim];
        if uniform {
            tensor::mean_rows_par(&refs, &mut mean);
        } else {
            let wsum: f32 = agg_weights.iter().sum();
            let norm: Vec<f32> = agg_weights.iter().map(|w| w / wsum).collect();
            tensor::weighted_mean_rows(&refs, &norm, &mut mean);
        }
        let mut u_ssq = Vec::with_capacity(self.meta.num_layers());
        let mut w_ssq = Vec::with_capacity(self.meta.num_layers());
        for lm in &self.meta.layers {
            let r = lm.offset..lm.offset + lm.size;
            u_ssq.push(tensor::ssq(&mean[r.clone()]) as f32);
            w_ssq.push(tensor::ssq(&self.params[r]) as f32);
        }
        let mut kappa = 0.0;
        if let Some(delta_sel) = self.luar_delta {
            self.luar.update_scores(&u_ssq, &w_ssq);
            self.luar.set_age_step(1 + mean_gap.round() as u32);
            kappa = self.luar.compose_update(&mut mean, &self.meta, RecycleMode::Recycle);
            let grad_norms: Vec<f64> =
                u_ssq.iter().map(|&s| (s as f64).max(0.0).sqrt()).collect();
            self.luar.select_next(SelectionScheme::Luar, delta_sel, &grad_norms, &mut self.rng);
        }
        tensor::axpy(1.0, &mean, &mut self.params);
        self.comm.record_wire_round(
            actives_len as u64,
            upload_layers,
            up_bytes_total,
            wire::dense_frame_len(&self.meta),
            down_total,
        );
        let (saved, fallbacks, _gap) = match &mut self.delta {
            Some(st) => st.drain_round(),
            None => (0, 0, 0.0),
        };
        self.comm.record_delta(saved, fallbacks);
        self.sim_seconds += round_secs;
        let train_loss = loss_sum / loss_count.max(1) as f64;
        self.round += 1;
        self.history.push(RoundRecord {
            round: self.round,
            train_loss,
            test_loss: tensor::ssq(&self.params),
            test_acc: self.params[0] as f64,
            up_bytes: self.comm.up_bytes,
            comm_ratio: self.comm.comm_ratio(),
            kappa,
            sim_seconds: self.sim_seconds,
            wire_bytes: up_bytes_total,
            tail_s,
            arrivals,
            version_gap: mean_gap,
        });
    }

    fn run_sync_round(&mut self) {
        let t = self.round as u64;
        let actives = self.cohort(t);
        let upload_layers = self.upload_layers();
        let bcast =
            wire::encode_broadcast(&self.params, &self.meta, &self.luar.recycle_set).unwrap();
        let bcast_self = bcast.len() as u64;
        let mut down_total = 0u64;
        if self.delta.is_some() {
            let params = self.params.clone();
            let recycle = self.luar.recycle_set.clone();
            let st = self.delta.as_mut().expect("checked above");
            st.note_bcast(t, &params, &self.meta);
            for &client in &actives {
                down_total +=
                    st.bcast_ledger_len(client, t, &self.meta, &recycle, bcast_self).unwrap();
            }
        } else {
            down_total = actives.len() as u64 * bcast_self;
        }
        let mut deltas: Vec<Vec<f32>> = Vec::with_capacity(actives.len());
        let mut timing_lens: Vec<u64> = Vec::with_capacity(actives.len());
        let mut loss_sum = 0.0f64;
        let mut up_total = 0u64;
        for &client in &actives {
            let (d, loss, ledger_len, self_len) = self.upload(client, t, t, &upload_layers);
            loss_sum += loss as f64;
            up_total += ledger_len;
            timing_lens.push(self_len);
            deltas.push(d);
        }
        // the schedule is always timed against self-contained lengths
        let outcome = self.net.round(&actives, bcast_self, &timing_lens);
        self.finish(
            &deltas,
            &outcome.included,
            &outcome.weights,
            &upload_layers,
            actives.len(),
            loss_sum,
            actives.len(),
            up_total,
            down_total,
            outcome.round_secs,
            outcome.straggler_tail_s,
            outcome.aggregated,
            0.0,
        );
    }

    fn dispatch_next(&mut self) {
        let (mut gen, mut idx) = {
            let rt = self.rt.as_ref().unwrap();
            (rt.sample_gen, rt.sample_idx as usize)
        };
        if idx >= ACTIVE {
            gen += 1;
            idx = 0;
        }
        let client = self.cohort(gen)[idx];
        {
            let rt = self.rt.as_mut().unwrap();
            rt.sample_gen = gen;
            rt.sample_idx = (idx + 1) as u64;
        }
        let version = self.rt.as_ref().unwrap().version;
        let upload_layers = self.upload_layers();
        let bcast =
            wire::encode_broadcast(&self.params, &self.meta, &self.luar.recycle_set).unwrap();
        let bcast_self = bcast.len() as u64;
        let bcast_ledger = if self.delta.is_some() {
            let params = self.params.clone();
            let recycle = self.luar.recycle_set.clone();
            let st = self.delta.as_mut().expect("checked above");
            st.note_bcast(version, &params, &self.meta);
            st.bcast_ledger_len(client, version, &self.meta, &recycle, bcast_self).unwrap()
        } else {
            bcast_self
        };
        let (delta, loss, ledger_len, self_len) =
            self.upload(client, gen, version, &upload_layers);
        // timing against self-contained lengths, ledger gets the delta
        let secs = self.net.client_secs(client, bcast_self, self_len);
        let rt = self.rt.as_mut().unwrap();
        let payload = UploadPayload {
            client,
            version,
            gen,
            delta,
            loss,
            frame_len: ledger_len,
            bcast_len: bcast_ledger,
        };
        rt.dispatch(payload, secs);
    }

    fn run_async_round(&mut self, c: usize, staleness: Staleness) {
        if self.rt.is_none() {
            self.rt = Some(AsyncRuntime::new(NUM_CLIENTS, c, ACTIVE, staleness));
        }
        loop {
            while self.rt.as_ref().unwrap().wants_dispatch() {
                self.dispatch_next();
            }
            let _ = self.rt.as_mut().unwrap().absorb_instant();
            if self.rt.as_ref().unwrap().ready() {
                let batch = self.rt.as_mut().unwrap().take_aggregation();
                let n = batch.uploads.len();
                let mut deltas: Vec<Vec<f32>> = Vec::with_capacity(n);
                let mut weights: Vec<f32> = Vec::with_capacity(n);
                let mut loss_sum = 0.0f64;
                let mut up_total = 0u64;
                for u in batch.uploads {
                    loss_sum += u.payload.loss as f64;
                    up_total += u.payload.frame_len;
                    weights.push(u.weight);
                    deltas.push(u.payload.delta);
                }
                let included = vec![true; n];
                let upload_layers = self.upload_layers();
                self.finish(
                    &deltas,
                    &included,
                    &weights,
                    &upload_layers,
                    n,
                    loss_sum,
                    n,
                    up_total,
                    batch.down_bytes,
                    batch.round_secs,
                    batch.tail_s,
                    n,
                    batch.mean_gap,
                );
                return;
            }
        }
    }

    fn run(&mut self, rounds: usize) {
        while self.round < rounds {
            match self.net.cfg.round_mode {
                RoundMode::Async { concurrency, staleness } => {
                    let c = if concurrency == 0 { ACTIVE } else { concurrency };
                    self.run_async_round(c, staleness);
                }
                _ => self.run_sync_round(),
            }
        }
    }
}

/// Every field of the round history that reflects the model path, the
/// simulated clock, or the scheduler — everything except bytes — must
/// be bit-identical between a dense-framed and a delta-framed run.
fn assert_trajectories_identical(dense: &History, framed: &History, tag: &str) {
    assert_eq!(dense.records.len(), framed.records.len(), "{tag}: round counts");
    for (d, f) in dense.records.iter().zip(&framed.records) {
        assert_eq!(d.round, f.round, "{tag}");
        let r = d.round;
        assert_eq!(d.train_loss.to_bits(), f.train_loss.to_bits(), "{tag} round {r}");
        assert_eq!(d.test_loss.to_bits(), f.test_loss.to_bits(), "{tag} round {r}");
        assert_eq!(d.test_acc.to_bits(), f.test_acc.to_bits(), "{tag} round {r}");
        assert_eq!(d.kappa.to_bits(), f.kappa.to_bits(), "{tag} round {r}");
        assert_eq!(d.sim_seconds.to_bits(), f.sim_seconds.to_bits(), "{tag} round {r}");
        assert_eq!(d.tail_s.to_bits(), f.tail_s.to_bits(), "{tag} round {r}");
        assert_eq!(d.arrivals, f.arrivals, "{tag} round {r}");
        assert_eq!(d.version_gap.to_bits(), f.version_gap.to_bits(), "{tag} round {r}");
    }
}

fn assert_ledger_shrinks(dense: &DeltaSim, framed: &DeltaSim, tag: &str) {
    assert!(
        framed.comm.up_bytes < dense.comm.up_bytes,
        "{tag}: delta uplink ledger {} must beat dense {}",
        framed.comm.up_bytes,
        dense.comm.up_bytes
    );
    assert!(
        framed.comm.down_bytes <= dense.comm.down_bytes,
        "{tag}: delta downlink ledger {} must never exceed dense {}",
        framed.comm.down_bytes,
        dense.comm.down_bytes
    );
    // the stacked saving is exactly the dense-vs-delta ledger gap
    let gap = (dense.comm.up_bytes - framed.comm.up_bytes)
        + (dense.comm.down_bytes - framed.comm.down_bytes);
    assert_eq!(framed.comm.delta_bytes_saved, gap, "{tag}: saved-bytes ledger");
    assert_eq!(dense.comm.delta_bytes_saved, 0, "{tag}: dense run must not save");
    assert_eq!(dense.comm.delta_fallbacks, 0, "{tag}: dense run must not fall back");
}

// ------------------------------------------------------------------ tests

/// Sync FedAvg and FedLUAR: delta framing changes the bytes, nothing
/// else. Uplink ledger strictly shrinks over a multi-round run.
#[test]
fn sync_delta_framing_is_trajectory_invariant_with_fewer_bytes() {
    for luar in [None, Some(2)] {
        let tag = format!("sync {luar:?}");
        let mut dense = DeltaSim::new(RoundMode::Sync, luar, 42, false);
        dense.run(6);
        let mut framed = DeltaSim::new(RoundMode::Sync, luar, 42, true);
        framed.run(6);
        assert_trajectories_identical(&dense.history, &framed.history, &tag);
        for (i, (x, y)) in dense.params.iter().zip(&framed.params).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag} param {i}: {x} vs {y}");
        }
        if luar.is_some() {
            assert_eq!(dense.luar.recycle_set, framed.luar.recycle_set, "{tag}");
        }
        assert_ledger_shrinks(&dense, &framed, &tag);
        // rounds 0 and 1 are first contact for both rotating cohorts:
        // ACTIVE uplink + ACTIVE downlink fallbacks each
        assert!(
            framed.comm.delta_fallbacks >= 4 * ACTIVE as u64,
            "{tag}: first-contact fallbacks uncounted ({})",
            framed.comm.delta_fallbacks
        );
    }
}

/// `async:c=all` with the zero staleness discount: same invariance,
/// same strictly-smaller uplink ledger.
#[test]
fn async_delta_framing_is_trajectory_invariant_with_fewer_bytes() {
    let amode = RoundMode::Async { concurrency: 0, staleness: Staleness::Const };
    for luar in [None, Some(2)] {
        let tag = format!("async {luar:?}");
        let mut dense = DeltaSim::new(amode, luar, 42, false);
        dense.run(6);
        let mut framed = DeltaSim::new(amode, luar, 42, true);
        framed.run(6);
        assert_trajectories_identical(&dense.history, &framed.history, &tag);
        for (i, (x, y)) in dense.params.iter().zip(&framed.params).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag} param {i}: {x} vs {y}");
        }
        assert_ledger_shrinks(&dense, &framed, &tag);
    }
}

/// Round one is all first contacts: every transmission in both
/// directions must ship self-contained and be counted as a fallback,
/// leaving the two ledgers byte-identical.
#[test]
fn first_round_fallbacks_are_counted_per_transmission() {
    let mut dense = DeltaSim::new(RoundMode::Sync, None, 7, false);
    dense.run(1);
    let mut framed = DeltaSim::new(RoundMode::Sync, None, 7, true);
    framed.run(1);
    assert_eq!(framed.comm.delta_fallbacks, 2 * ACTIVE as u64, "one per direction per client");
    assert_eq!(framed.comm.delta_bytes_saved, 0, "nothing to delta against yet");
    assert_eq!(framed.comm.up_bytes, dense.comm.up_bytes);
    assert_eq!(framed.comm.down_bytes, dense.comm.down_bytes);
}

/// From the third round on, the rotating cohorts have both uplink and
/// downlink references: uplink fallbacks stop and savings accrue every
/// round (the correlated synthetic deltas guarantee smaller frames).
#[test]
fn warm_references_save_every_round() {
    let mut framed = DeltaSim::new(RoundMode::Sync, None, 11, true);
    framed.run(2);
    let after_two = framed.comm.delta_bytes_saved;
    let fallbacks_two = framed.comm.delta_fallbacks;
    framed.run(6);
    // savings strictly grow each of rounds 3..6
    assert!(
        framed.comm.delta_bytes_saved > after_two,
        "warm uplink references must save bytes ({} vs {after_two})",
        framed.comm.delta_bytes_saved
    );
    // no *uplink* fallbacks after both cohorts have uploaded once:
    // any later fallbacks can only come from the downlink ring
    let later = framed.comm.delta_fallbacks - fallbacks_two;
    assert!(
        later <= 4 * ACTIVE as u64,
        "uplink fallbacks persisted past first contact ({later})"
    );
}
