//! Residual (delta) framing equivalence (engine-free): the same
//! miniature `fl::Server` mirror as `integration_async.rs` (the shared
//! `SimServer` in `tests/common/mod.rs`), but with `fl::DeltaFrameState`
//! threaded through the uplink and downlink accounting exactly as
//! `Server` wires it — reference snapshots from decoded uploads and
//! recent broadcasts, delta frames on the comm ledger, self-contained
//! lengths on the link schedule.
//!
//! Pins the PR's acceptance invariants:
//! * **trajectory invariance** — delta-framed FedAvg and FedLUAR runs
//!   (sync and `async:c=N`) are bit-identical in every model-path and
//!   clock field to their dense-framed twins; only byte columns move;
//! * **strictly fewer bytes** — uplink ledger bytes strictly shrink on
//!   runs of two or more rounds, downlink never grows, and
//!   `delta_bytes_saved` equals the dense-vs-delta ledger gap exactly;
//! * **fallbacks counted** — every first-contact transmission (both
//!   directions) shows up in `delta_fallbacks`.
//!
//! The synthetic client deltas here (`DeltaFlavor::Correlated`) are
//! cross-round correlated by construction: one base draw per client,
//! per-generation noise XORed into the low 16 mantissa bits.
//! Successive uploads then differ only in bytes the XOR coder stores
//! at 2-per-element, so uplink savings are a deterministic guarantee,
//! not a distributional accident.

mod common;

use common::{assert_trajectories_identical, SimServer, ACTIVE};
use fedluar::net::{RoundMode, Staleness};

fn assert_ledger_shrinks(dense: &SimServer, framed: &SimServer, tag: &str) {
    assert!(
        framed.comm.up_bytes < dense.comm.up_bytes,
        "{tag}: delta uplink ledger {} must beat dense {}",
        framed.comm.up_bytes,
        dense.comm.up_bytes
    );
    assert!(
        framed.comm.down_bytes <= dense.comm.down_bytes,
        "{tag}: delta downlink ledger {} must never exceed dense {}",
        framed.comm.down_bytes,
        dense.comm.down_bytes
    );
    // the stacked saving is exactly the dense-vs-delta ledger gap
    let gap = (dense.comm.up_bytes - framed.comm.up_bytes)
        + (dense.comm.down_bytes - framed.comm.down_bytes);
    assert_eq!(framed.comm.delta_bytes_saved, gap, "{tag}: saved-bytes ledger");
    assert_eq!(dense.comm.delta_bytes_saved, 0, "{tag}: dense run must not save");
    assert_eq!(dense.comm.delta_fallbacks, 0, "{tag}: dense run must not fall back");
}

// ------------------------------------------------------------------ tests

/// Sync FedAvg and FedLUAR: delta framing changes the bytes, nothing
/// else. Uplink ledger strictly shrinks over a multi-round run.
#[test]
fn sync_delta_framing_is_trajectory_invariant_with_fewer_bytes() {
    for luar in [None, Some(2)] {
        let tag = format!("sync {luar:?}");
        let mut dense = SimServer::new_delta(RoundMode::Sync, luar, 42, false);
        dense.run(6);
        let mut framed = SimServer::new_delta(RoundMode::Sync, luar, 42, true);
        framed.run(6);
        assert_trajectories_identical(&dense.history, &framed.history, &tag);
        for (i, (x, y)) in dense.params.iter().zip(&framed.params).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag} param {i}: {x} vs {y}");
        }
        if luar.is_some() {
            assert_eq!(dense.luar.recycle_set, framed.luar.recycle_set, "{tag}");
        }
        assert_ledger_shrinks(&dense, &framed, &tag);
        // rounds 0 and 1 are first contact for both rotating cohorts:
        // ACTIVE uplink + ACTIVE downlink fallbacks each
        assert!(
            framed.comm.delta_fallbacks >= 4 * ACTIVE as u64,
            "{tag}: first-contact fallbacks uncounted ({})",
            framed.comm.delta_fallbacks
        );
    }
}

/// `async:c=all` with the zero staleness discount: same invariance,
/// same strictly-smaller uplink ledger.
#[test]
fn async_delta_framing_is_trajectory_invariant_with_fewer_bytes() {
    let amode = RoundMode::Async { concurrency: 0, staleness: Staleness::Const };
    for luar in [None, Some(2)] {
        let tag = format!("async {luar:?}");
        let mut dense = SimServer::new_delta(amode, luar, 42, false);
        dense.run(6);
        let mut framed = SimServer::new_delta(amode, luar, 42, true);
        framed.run(6);
        assert_trajectories_identical(&dense.history, &framed.history, &tag);
        for (i, (x, y)) in dense.params.iter().zip(&framed.params).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag} param {i}: {x} vs {y}");
        }
        assert_ledger_shrinks(&dense, &framed, &tag);
    }
}

/// Round one is all first contacts: every transmission in both
/// directions must ship self-contained and be counted as a fallback,
/// leaving the two ledgers byte-identical.
#[test]
fn first_round_fallbacks_are_counted_per_transmission() {
    let mut dense = SimServer::new_delta(RoundMode::Sync, None, 7, false);
    dense.run(1);
    let mut framed = SimServer::new_delta(RoundMode::Sync, None, 7, true);
    framed.run(1);
    assert_eq!(framed.comm.delta_fallbacks, 2 * ACTIVE as u64, "one per direction per client");
    assert_eq!(framed.comm.delta_bytes_saved, 0, "nothing to delta against yet");
    assert_eq!(framed.comm.up_bytes, dense.comm.up_bytes);
    assert_eq!(framed.comm.down_bytes, dense.comm.down_bytes);
}

/// From the third round on, the rotating cohorts have both uplink and
/// downlink references: uplink fallbacks stop and savings accrue every
/// round (the correlated synthetic deltas guarantee smaller frames).
#[test]
fn warm_references_save_every_round() {
    let mut framed = SimServer::new_delta(RoundMode::Sync, None, 11, true);
    framed.run(2);
    let after_two = framed.comm.delta_bytes_saved;
    let fallbacks_two = framed.comm.delta_fallbacks;
    framed.run(6);
    // savings strictly grow each of rounds 3..6
    assert!(
        framed.comm.delta_bytes_saved > after_two,
        "warm uplink references must save bytes ({} vs {after_two})",
        framed.comm.delta_bytes_saved
    );
    // no *uplink* fallbacks after both cohorts have uploaded once:
    // any later fallbacks can only come from the downlink ring
    let later = framed.comm.delta_fallbacks - fallbacks_two;
    assert!(
        later <= 4 * ACTIVE as u64,
        "uplink fallbacks persisted past first contact ({later})"
    );
}
