//! Minimal in-tree stand-in for the `anyhow` crate (offline build: no
//! registry access). Implements exactly the surface this repo uses —
//! `Error`, `Result<T>`, `anyhow!`, `bail!`, and the `Context` trait
//! on both `Result` and `Option` — with anyhow-compatible semantics:
//!
//! * `{}` displays the outermost message, `{:#}` the full context
//!   chain joined with ": " (what `main.rs` prints on fatal errors);
//! * `Debug` renders the chain as anyhow's `Caused by:` block so
//!   `unwrap()`/`expect()` panics stay readable in test output;
//! * any `std::error::Error` converts via `?` (io, parse, utf8, fmt).
//!
//! Not implemented (unused here): downcasting, backtraces, `ensure!`.

use std::fmt;

/// Context chain, outermost first (index 0 is the newest `.context`).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { chain: vec![m.to_string()] }
    }

    fn wrap<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The innermost message in the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach human context to a failure; mirrors `anyhow::Context`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt {args}")` — construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("fmt {args}")` — early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root {}", 42)
    }

    #[test]
    fn display_and_alternate() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 42");
        assert_eq!(e.root_cause(), "root 42");
    }

    #[test]
    fn std_errors_convert() {
        let r: Result<i32> = "abc".parse::<i32>().context("parsing");
        let e = r.unwrap_err();
        assert!(format!("{e:#}").starts_with("parsing: "));
        // plain `?` conversion
        fn inner() -> Result<i32> {
            Ok("7".parse::<i32>()?)
        }
        assert_eq!(inner().unwrap(), 7);
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u8).with_context(|| "x").unwrap(), 3);
    }

    #[test]
    fn debug_shows_chain() {
        let e = fails().context("mid").context("outer").unwrap_err();
        let d = format!("{e:?}");
        assert!(d.contains("outer"));
        assert!(d.contains("Caused by:"));
        assert!(d.contains("root 42"));
    }
}
